"""EeiServer: continuous batching, shape buckets, program-cache bounds.

The serving machinery's contract: coalescing + bucket padding + slicing add
*zero* numerical change (server output is bit-identical to ``SolverEngine``
on the equivalent padded stack, and bit-identical k-slices of it), padded
rows/components never leak into results, and a mixed 100-request stream
executes through at most one compile per distinct shape bucket.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import (
    EeiServer,
    ProgramCache,
    ShapeBucket,
    SolverEngine,
    SolverPlan,
)
from repro.engine.server import make_eei_stream

PLAN = SolverPlan(method="eei_tridiag", backend="jnp")


def _sym(rng, n: int) -> np.ndarray:
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


def _serve(server: EeiServer, stream):
    futs = [server.submit(a, k) for a, k in stream]
    server.flush()
    return [f.result() for f in futs]


# ---------------------------------------------------------------------------
# Numerical contract
# ---------------------------------------------------------------------------


def test_full_stack_bit_identical_to_engine_program():
    """One full stack of mixed-k requests == engine.topk on the same stack.

    The server's value-add (queueing, bucketing, the program cache, async
    dispatch, per-request slicing) must be numerically invisible: for
    aligned n the padded stack *is* the engine's stack, and heterogeneous k
    rides the group-max program with per-request slices that are bitwise
    equal to what smaller-k programs produce (k-selected stages are
    per-pair independent).
    """
    rng = np.random.default_rng(0)
    mats = [_sym(rng, 16) for _ in range(8)]
    ks = [4, 2, 1, 3, 4, 4, 2, 3]
    server = EeiServer(PLAN, max_batch=8)
    results = _serve(server, list(zip(mats, ks)))
    assert server.stats()["stacks_dispatched"] == 1

    ref = SolverEngine(PLAN).topk(jnp.asarray(np.stack(mats)), 4)
    lam_ref = np.asarray(ref.eigenvalues)
    vec_ref = np.asarray(ref.vectors)
    for i, ((lam, vec), k) in enumerate(zip(results, ks)):
        assert lam.shape == (k,) and vec.shape == (k, 16)
        np.testing.assert_array_equal(lam, lam_ref[i, -k:])
        np.testing.assert_array_equal(vec, vec_ref[i, -k:])


def test_mixed_stream_matches_per_request_topk():
    """Heterogeneous (n, k) stream vs one engine.topk call per request.

    Per-request programs run at b=1 while the server batches, so float32
    XLA fusions may differ in the last bits — agreement is to tight
    tolerance, and eigenvalues/vectors land in the request's own shapes.
    """
    rng = np.random.default_rng(1)
    stream = [(_sym(rng, n), k)
              for n, k in [(16, 4), (24, 2), (16, 1), (32, 4), (24, 3),
                           (16, 2), (32, 1), (16, 4), (24, 4), (32, 2)]]
    server = EeiServer(PLAN, max_batch=4)
    results = _serve(server, stream)
    engine = SolverEngine(PLAN)
    for (a, k), (lam, vec) in zip(stream, results):
        ref = engine.topk(jnp.asarray(a), k)
        np.testing.assert_allclose(lam, np.asarray(ref.eigenvalues),
                                   rtol=1e-5, atol=1e-5)
        err = np.minimum(np.abs(vec - np.asarray(ref.vectors)),
                         np.abs(vec + np.asarray(ref.vectors))).max()
        assert err < 5e-3, err


@pytest.mark.parametrize("largest", [True, False])
def test_guard_padded_n_never_leaks(largest):
    """Unaligned n pads to the bucket via guard-diagonal embedding; results
    must carry only the request's own eigenpairs (vs an eigh oracle)."""
    rng = np.random.default_rng(2)
    stream = [(_sym(rng, n), 3) for n in (9, 13, 17, 21, 30, 9, 13, 11)]
    server = EeiServer(PLAN, max_batch=4)
    futs = [server.submit(a, k, largest=largest) for a, k in stream]
    server.flush()
    for (a, k), fut in zip(stream, futs):
        lam, vec = fut.result()
        n = a.shape[0]
        assert lam.shape == (k,) and vec.shape == (k, n)
        w, v = np.linalg.eigh(a.astype(np.float64))
        w_sel = w[-k:] if largest else w[:k]
        v_sel = (v[:, -k:] if largest else v[:, :k]).T
        np.testing.assert_allclose(lam, w_sel, rtol=1e-4, atol=1e-4)
        # guard eigenvalues sit outside the spectrum — none may appear
        assert np.all(lam >= w[0] - 1e-3) and np.all(lam <= w[-1] + 1e-3)
        err = np.abs(np.abs(vec) - np.abs(v_sel)).max()
        assert err < 5e-3, err


def test_batch_padding_rows_never_leak():
    """A partial stack (3 requests into a pow2-4 bucket) returns exactly 3
    results; the padded row is sliced off before futures resolve."""
    rng = np.random.default_rng(3)
    stream = [(_sym(rng, 16), 2) for _ in range(3)]
    server = EeiServer(PLAN, max_batch=8)
    results = _serve(server, stream)
    assert len(results) == 3
    assert server.stats()["requests_completed"] == 3
    bucket = server.cache.buckets()[0]
    assert bucket.b == 4  # 3 requests padded to the pow2 bucket
    engine = SolverEngine(PLAN)
    for (a, k), (lam, vec) in zip(stream, results):
        ref = engine.topk(jnp.asarray(a), k)
        np.testing.assert_allclose(lam, np.asarray(ref.eigenvalues),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Program-cache bounds (the compile-amortization contract)
# ---------------------------------------------------------------------------


def test_program_cache_bounded_by_buckets_on_100_request_stream():
    stream = make_eei_stream(100, 16, 4, seed=7, mixed=True)
    server = EeiServer(PLAN, max_batch=16)
    results = _serve(server, stream)
    assert len(results) == 100
    stats = server.stats()
    assert stats["requests_completed"] == 100
    # one compile per distinct bucket, nothing per-request / per-shape
    assert server.cache.compiles == stats["distinct_buckets"]
    assert server.cache.compiles == len(set(server.cache.buckets()))
    assert server.cache.compiles <= 8  # 100 requests, single-digit programs
    assert server.cache.hits == stats["stacks_dispatched"] - \
        server.cache.compiles
    # replaying the same stream is all hits, zero compiles
    before = server.cache.compiles
    _serve(server, stream)
    assert server.cache.compiles == before


def test_warm_server_replay_is_steady_state():
    stream = make_eei_stream(40, 16, 4, seed=8, mixed=True)
    server = EeiServer(PLAN, max_batch=8)
    _serve(server, stream)
    server.reset_stats()
    results = _serve(server, stream)
    assert len(results) == 40
    stats = server.stats()
    assert stats["program_compiles"] == 0  # warm: buckets bound compilation
    assert stats["program_hits"] == stats["stacks_dispatched"]
    assert stats["p99_latency_ms"] >= stats["p50_latency_ms"] >= 0.0


def test_shape_bucket_rounding():
    b = ShapeBucket.for_requests(5, 17, 3, True)
    assert b == ShapeBucket(b=8, n=24, k=4, largest=True)
    # k bucket never exceeds the padded n
    b = ShapeBucket.for_requests(1, 17, 17, False)
    assert b.n == 24 and b.k == 24 and b.b == 1
    assert ShapeBucket.for_requests(16, 16, 4, True) == \
        ShapeBucket(16, 16, 4, True)


def test_program_cache_counters():
    cache = ProgramCache()
    bucket = ShapeBucket(2, 16, 2, True)
    p1 = cache.get(bucket, PLAN, jnp.float32)
    p2 = cache.get(bucket, PLAN, jnp.float32)
    assert p1 is p2
    assert (cache.hits, cache.misses, cache.compiles, len(cache)) == \
        (1, 1, 1, 1)
    cache.get(ShapeBucket(2, 16, 2, False), PLAN, jnp.float32)
    assert cache.compiles == 2 and len(cache) == 2


def test_bucket_rounds_up_to_mesh_batch_axis(monkeypatch):
    """A sharded plan needs stacks divisible by the mesh batch axis; a
    partial group's pow2 bucket must round up to it (the engine pads its
    chunks the same way), not crash inside shard_map."""
    monkeypatch.setattr(SolverPlan, "batch_axis_size",
                        property(lambda self: 8))
    rng = np.random.default_rng(11)
    stream = [(_sym(rng, 16), 2) for _ in range(3)]
    server = EeiServer(PLAN, max_batch=16)
    results = _serve(server, stream)
    assert server.cache.buckets()[0].b == 8  # pow2(3)=4, padded to axis 8
    engine = SolverEngine(PLAN)
    for (a, k), (lam, vec) in zip(stream, results):
        np.testing.assert_allclose(
            lam, np.asarray(engine.topk(jnp.asarray(a), k).eigenvalues),
            rtol=1e-5, atol=1e-5)


def test_non_pow2_max_batch_floors_to_bound():
    """Stack buckets are pow2 — max_batch=48 must serve stacks of at most
    32, never round a full group up past the operator's bound."""
    server = EeiServer(PLAN, max_batch=48)
    assert server.max_batch == 32
    assert EeiServer(PLAN, max_batch=16).max_batch == 16
    assert EeiServer(PLAN, max_batch=1).max_batch == 1


def test_submit_validation():
    server = EeiServer(PLAN)
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        server.submit(rng.standard_normal((4, 5)), 1)
    with pytest.raises(ValueError):
        server.submit(_sym(rng, 4), 0)
    with pytest.raises(ValueError):
        server.submit(_sym(rng, 4), 5)
    with pytest.raises(ValueError):
        EeiServer(PLAN, max_batch=0)
    with pytest.raises(ValueError):
        EeiServer(PLAN, max_inflight=0)


def test_partial_group_does_not_block_other_full_stacks():
    """Head-of-line regression: a partial group in one coalesce key must
    not delay a full stack forming in another key."""
    rng = np.random.default_rng(10)
    server = EeiServer(PLAN, max_batch=4)
    f_head = server.submit(_sym(rng, 16), 2)  # partial n=16 group sits first
    futs = [server.submit(_sym(rng, 32), 2) for _ in range(4)]
    # the full n=32 stack dispatched despite the queued partial n=16 group
    assert server.stats()["stacks_dispatched"] == 1
    assert not f_head.done()
    server.flush()
    assert f_head.done() and all(f.done() for f in futs)
    assert server.stats()["stacks_dispatched"] == 2


def test_failed_dispatch_resolves_futures_with_exception(monkeypatch):
    """A compile/launch failure must fail the group's futures, not strand
    callers blocked on future.result() (and not kill the server)."""
    rng = np.random.default_rng(12)
    server = EeiServer(PLAN, max_batch=4)

    def boom(*a, **k):
        raise RuntimeError("synthetic compile failure")

    monkeypatch.setattr(server.cache, "get", boom)
    futs = [server.submit(_sym(rng, 16), 2) for _ in range(4)]
    assert all(f.done() for f in futs)  # resolved, not stranded
    with pytest.raises(RuntimeError, match="synthetic"):
        futs[0].result()
    assert server.stats()["requests_failed"] == 4
    # the server keeps serving after a failed group
    monkeypatch.undo()
    ok = server.submit(_sym(rng, 16), 2)
    server.flush()
    assert ok.result().eigenvalues.shape == (2,)


def test_double_buffer_keeps_stacks_inflight():
    """With max_inflight=2, dispatching 3 full stacks retires only the
    oldest eagerly; the rest resolve on flush()."""
    rng = np.random.default_rng(9)
    server = EeiServer(PLAN, max_batch=2, max_inflight=2)
    futs = [server.submit(_sym(rng, 16), 2) for _ in range(6)]
    # 3 full stacks dispatched by pump(); at most one retired so far
    assert server.stats()["stacks_dispatched"] == 3
    assert sum(f.done() for f in futs) <= 2
    server.flush()
    assert all(f.done() for f in futs)
    assert server.stats()["requests_completed"] == 6
