"""SpectralSession: the streaming rank-1 update path against the eigh
oracle, the drift monitor's three triggers, and stateful serving sessions.

The session contract: every window a session hands back is either
residual-verified against the *updated* matrix or freshly re-solved —
the warm path can never silently return stale eigenpairs.  The property
suite drives random rank-1 perturbation streams through all four
backends and checks eigh-oracle conformance after every step, including
the adversarial case where the perturbation pushes an out-of-window
eigenvalue across the window boundary (an eigenvalue-ordering swap the
warm brackets cannot track without the monitor).

Serving coverage rides along: per-session sticky execution in
``EeiServer`` (both threaded and caller-driven pumps), degrade-to-host
when the fast path's backend is broken, fleet stickiness + failover
reopen, and the adaptive-linger regression test (a hot coalesce key must
stop waiting out the full linger timeout).
"""

import threading
import time

import jax
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.engine import (
    DegradedResult,
    EeiFleet,
    EeiServer,
    ProgramCache,
    Rank1Update,
    ServerClosed,
    SessionConfig,
    SolverEngine,
    SolverPlan,
    verify_topk_host,
)

PLAN = SolverPlan(method="eei_tridiag", backend="jnp")
BACKENDS = ["reference", "jnp", "pallas", "sharded"]

#: One cache across the module (mirrors test_server): serving tests reuse
#: compiled programs instead of recompiling per test.
SHARED_CACHE = ProgramCache()


def _plan(backend: str) -> SolverPlan:
    mesh = jax.make_mesh((1, 1), ("data", "model")) \
        if backend == "sharded" else None
    return SolverPlan(method="eei_tridiag", backend=backend, mesh=mesh)


def _sym(rng, n: int) -> np.ndarray:
    a = rng.standard_normal((n, n))
    return (a + a.T) / 2


def _oracle_window(a: np.ndarray, k: int, largest: bool = True):
    lam = np.linalg.eigvalsh(np.asarray(a, np.float64))
    return lam[-k:] if largest else lam[:k]


def _assert_conformant(a: np.ndarray, res, k: int, largest: bool = True,
                       rtol: float = 5e-3) -> None:
    """The session's window must match the float64 eigh oracle on the
    accumulated matrix: eigenvalues to ``rtol`` of the spectral scale,
    eigenvectors through the residual check (sign/degeneracy safe)."""
    lam = np.asarray(res.eigenvalues, np.float64)
    vec = np.asarray(res.vectors, np.float64)
    ref = _oracle_window(a, k, largest)
    scale = max(np.linalg.norm(a), 1e-30)
    np.testing.assert_allclose(lam, ref, atol=rtol * scale, rtol=0)
    flags = verify_topk_host(np.asarray(a), lam, vec)
    assert bool(np.all(flags.ok)), \
        f"window failed residual verification: {flags}"


# ---------------------------------------------------------------------------
# Engine-level update path: oracle conformance on all four backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_update_stream_matches_eigh_oracle(backend, rng):
    """A stream of random rank-1 updates tracks the eigh oracle at every
    step, on every backend, mixing warm-path and monitor-forced solves."""
    n, k = 16, 3
    engine = SolverEngine(_plan(backend))
    a = _sym(rng, n)
    session = engine.open_session(a, k)
    _assert_conformant(a, session.result(), k)
    for step in range(6):
        u = rng.standard_normal(n) * (0.3 if step % 2 else 1.5)
        sign = -1 if step == 4 else 1
        a = a + sign * np.outer(u, u)
        res = engine.update(session, Rank1Update(u, sign))
        _assert_conformant(a, res, k)
    stats = session.stats()
    assert stats["updates_total"] == 6
    assert stats["fast_updates"] + stats["full_resolves"] == 6
    assert stats["fast_updates"] >= 1, \
        "no update took the warm path — brackets or verify are broken"


@pytest.mark.parametrize("backend", BACKENDS)
def test_update_survives_window_crossing_swap(backend, rng):
    """Adversarial eigenvalue-ordering swap: the update is aligned with an
    eigenvector *outside* the retained window and lifts its eigenvalue
    across the window boundary.  A warm start that blindly trusted the old
    ordering would return the stale window; the monitor (drift bound or
    the residual verify) must force a re-solve instead."""
    n, k = 12, 2
    engine = SolverEngine(_plan(backend))
    a = _sym(rng, n)
    lam, v = np.linalg.eigh(a)
    session = engine.open_session(
        a, k, config=SessionConfig(buffer=2, drift_bound=100.0))
    # Lift the *smallest* eigenvalue far above the current top: its
    # eigenvector is invariant, so A' = A + c^2 v0 v0^T swaps it to rank 1.
    c = np.sqrt(lam[-1] - lam[0] + 5.0)
    u = c * v[:, 0]
    a_new = a + np.outer(u, u)
    res = engine.update(session, Rank1Update(u, 1))
    _assert_conformant(a_new, res, k)
    # The new top eigenvalue is the lifted one — the ordering really swapped.
    assert abs(float(np.asarray(res.eigenvalues)[-1]) -
               (lam[0] + c * c)) < 1e-2 * np.linalg.norm(a_new)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from([8, 16]),
       k=st.integers(1, 4), sign=st.sampled_from([-1, 1]),
       scale_exp=st.integers(-2, 1))
def test_property_update_is_oracle_conformant(seed, n, k, sign, scale_exp):
    """Random rank-1 perturbations of random magnitude (1e-2 .. 1e1 of the
    spectral scale) stay eigh-oracle-conformant to float32 tolerance —
    warm path and monitor-forced path alike."""
    rng = np.random.default_rng(seed)
    engine = SolverEngine(PLAN)
    a = _sym(rng, n)
    session = engine.open_session(a, k)
    u = rng.standard_normal(n) * float(10.0 ** scale_exp)
    a_new = a + sign * np.outer(u, u)
    res = engine.update(session, Rank1Update(u, sign))
    _assert_conformant(a_new, res, k)


def test_property_update_all_backends_one_seed(rng):
    """The same perturbation stream is oracle-conformant on every backend
    (the hypothesis property above fuzzes the jnp backend; this pins the
    other three to the identical stream)."""
    n, k = 8, 2
    a0 = _sym(rng, n)
    us = [rng.standard_normal(n) for _ in range(3)]
    for backend in BACKENDS:
        engine = SolverEngine(_plan(backend))
        a = a0.copy()
        session = engine.open_session(a, k)
        for u in us:
            a = a + np.outer(u, u)
            _assert_conformant(a, engine.update(session, Rank1Update(u, 1)),
                               k)


# ---------------------------------------------------------------------------
# Drift monitor
# ---------------------------------------------------------------------------


def test_drift_monitor_forces_full_resolve(rng):
    """k consecutive updates past the drift bound each force a verified
    full re-solve — the warm path never runs on an over-drifted session."""
    n, k = 12, 2
    engine = SolverEngine(PLAN)
    a = _sym(rng, n)
    session = engine.open_session(
        a, k, config=SessionConfig(drift_bound=1e-9))
    for _ in range(4):
        u = rng.standard_normal(n)
        a = a + np.outer(u, u)
        _assert_conformant(a, engine.update(session, Rank1Update(u, 1)), k)
    stats = session.stats()
    assert stats["fast_updates"] == 0
    assert stats["full_resolves"] == 4
    assert stats["resolves_by_cause"].get("drift") == 4


def test_drift_accumulates_across_small_updates(rng):
    """The bound is on *accumulated* |rho|/||A||_F: many small updates,
    each individually under the bound, must still trip it."""
    n, k = 12, 2
    engine = SolverEngine(PLAN)
    a = _sym(rng, n) * 10.0
    session = engine.open_session(
        a, k, config=SessionConfig(drift_bound=0.05))
    per_step = []
    for _ in range(12):
        u = rng.standard_normal(n) * 0.3
        a = a + np.outer(u, u)
        engine.update(session, Rank1Update(u, 1))
        per_step.append(session.stats()["full_resolves"])
    assert session.stats()["resolves_by_cause"].get("drift", 0) >= 1
    assert per_step[0] == 0, \
        "first tiny update should not trip an accumulation bound"
    _assert_conformant(a, session.result(), k)


def test_cadence_cap_bounds_staleness(rng):
    """Even with drift and verify green, ``max_updates`` fast updates force
    a re-solve — worst-case staleness is bounded."""
    n, k = 12, 2
    engine = SolverEngine(PLAN)
    a = _sym(rng, n) * 100.0
    session = engine.open_session(
        a, k, config=SessionConfig(drift_bound=1e9, max_updates=2))
    for _ in range(6):
        u = rng.standard_normal(n) * 1e-3
        a = a + np.outer(u, u)
        engine.update(session, Rank1Update(u, 1))
    stats = session.stats()
    assert stats["resolves_by_cause"].get("cadence") == 2
    assert stats["fast_updates"] == 4
    _assert_conformant(a, session.result(), k)


# ---------------------------------------------------------------------------
# Update request surface / edge cases
# ---------------------------------------------------------------------------


def test_rank_r_update_decomposes_sequentially(rng):
    """A sequence of Rank1Updates applies as r sequential rank-1 steps."""
    n, k = 10, 2
    engine = SolverEngine(PLAN)
    a = _sym(rng, n)
    session = engine.open_session(a, k)
    us = [rng.standard_normal(n) for _ in range(3)]
    signs = [1, -1, 1]
    for u, s in zip(us, signs):
        a = a + s * np.outer(u, u)
    res = engine.update(
        session, [Rank1Update(u, s) for u, s in zip(us, signs)])
    assert session.stats()["updates_total"] == 3
    _assert_conformant(a, res, k)


def test_update_rejects_malformed_requests(rng):
    n = 8
    engine = SolverEngine(PLAN)
    a = _sym(rng, n)
    session = engine.open_session(a, 2)
    with pytest.raises(ValueError, match="shape"):
        engine.update(session, Rank1Update(np.ones(n + 1)))
    with pytest.raises(ValueError, match="finite"):
        engine.update(session, Rank1Update(np.full(n, np.nan)))
    with pytest.raises(ValueError, match="sign"):
        engine.update(session, Rank1Update(np.ones(n), 2))
    # Zero vector: A + 0 = A — a no-op, not an error, and drifts nothing.
    before = session.stats()["drift"]
    engine.update(session, Rank1Update(np.zeros(n)))
    assert session.stats()["drift"] == before
    _assert_conformant(a, session.result(), 2)


def test_tuple_and_array_update_forms(rng):
    """``(u, sign)`` tuples and bare arrays coerce to Rank1Update."""
    n = 8
    engine = SolverEngine(PLAN)
    a = _sym(rng, n)
    session = engine.open_session(a, 2)
    u = rng.standard_normal(n)
    a = a + np.outer(u, u)
    _assert_conformant(a, engine.update(session, (u, 1)), 2)
    w = rng.standard_normal(n)
    a = a + np.outer(w, w)
    _assert_conformant(a, engine.update(session, w), 2)


# ---------------------------------------------------------------------------
# EeiServer stateful sessions
# ---------------------------------------------------------------------------


def _server(**kwargs) -> EeiServer:
    kwargs.setdefault("plan", PLAN)
    kwargs.setdefault("cache", SHARED_CACHE)
    return EeiServer(**kwargs)


@pytest.mark.parametrize("threaded", [False, True])
def test_server_session_update_stream(threaded, rng):
    """Sticky session updates through the server resolve in order and
    match the oracle — caller-driven and threaded pumps alike."""
    n, k = 12, 2
    kwargs = dict(linger_ms=1.0) if threaded else {}
    with _server(**kwargs) as server:
        a = _sym(rng, n)
        sid = server.open_session(a, k)
        futs = []
        for _ in range(4):
            u = rng.standard_normal(n)
            a = a + np.outer(u, u)
            futs.append((a.copy(), server.submit_update(sid, u)))
        for a_t, fut in futs:
            _assert_conformant(a_t, fut.result(timeout=60), k)
        snap = server.session_result(sid)
        _assert_conformant(a, snap, k)
        stats = server.stats()
        assert stats["sessions_open"] == 1
        assert stats["session_updates"] == 4
        assert stats["session_fast_updates"] + \
            stats["session_full_resolves"] == 4
        assert server.session_stats(sid)["updates_total"] == 4
        server.close_session(sid)
        assert server.stats()["sessions_open"] == 0
        with pytest.raises(KeyError):  # the sid no longer resolves
            server.submit_update(sid, rng.standard_normal(n))


def test_server_session_degrades_to_host_solve(rng):
    """A broken fast path degrades to a host eigh from the mirror: the
    future resolves with a flagged DegradedResult, never an error, and
    the window still matches the oracle (PR-7 fallback semantics)."""
    n, k = 10, 2
    with _server() as server:
        a = _sym(rng, n)
        sid = server.open_session(a, k)
        rec = server._sessions[sid]

        class _Broken:
            def update(self, *a, **kw):
                raise RuntimeError("backend down")

        rec.engine = _Broken()
        u = rng.standard_normal(n)
        a = a + np.outer(u, u)
        res = server.submit_update(sid, u).result(timeout=60)
        assert isinstance(res, DegradedResult)
        assert res.fallback == "host_reseed"
        _assert_conformant(a, res, k)
        assert server.stats()["session_degraded"] == 1


def test_server_session_malformed_update_fails_future(rng):
    """Bad requests fail the future directly — degrading cannot fix a
    wrong-shaped vector, and masking it would hide a caller bug."""
    n = 8
    with _server() as server:
        sid = server.open_session(_sym(rng, n), 2)
        with pytest.raises(ValueError):
            server.submit_update(sid, np.ones(n + 3)).result(timeout=60)
        assert server.stats()["requests_failed"] == 1


def test_server_close_fails_pending_session_ops(rng):
    """A non-draining close resolves queued session updates with
    ServerClosed instead of dropping them."""
    n = 8
    server = _server(linger_ms=50.0)
    sid = server.open_session(_sym(rng, n), 2)
    # Park the executor inside an update so followers stay queued.
    release = threading.Event()
    real_engine = server._sessions[sid].engine

    class _Slow:
        def update(self, *a, **kw):
            release.wait(10.0)
            return real_engine.update(*a, **kw)

    server._sessions[sid].engine = _Slow()
    rng_u = np.random.default_rng(7)
    futs = [server.submit_update(sid, rng_u.standard_normal(n))
            for _ in range(3)]
    time.sleep(0.05)  # let the executor pick up the first op
    server.close(drain=False, timeout=10.0)
    release.set()
    outcomes = []
    for f in futs:
        try:
            f.result(timeout=10)
            outcomes.append("ok")
        except ServerClosed:
            outcomes.append("closed")
    assert outcomes.count("closed") >= 2, outcomes
    assert all(o in ("ok", "closed") for o in outcomes)


# ---------------------------------------------------------------------------
# Adaptive linger
# ---------------------------------------------------------------------------


def test_adaptive_linger_trims_hot_key(rng):
    """Regression: a hot coalesce key (arrivals every ~2 ms) must not wait
    out a 2000 ms linger for its partial stacks.  The per-key EWMA arrival
    rate shrinks the effective linger to a few expected gaps, so the whole
    stream resolves in well under one base linger."""
    n, k = 8, 2
    # max_batch far above the stream size: the stack stays *partial*
    # forever, so without the adaptive trim it would sit the full 2 s.
    with _server(linger_ms=2000.0, max_batch=64,
                 record_dispatches=True) as server:
        futs = []
        for _ in range(20):
            futs.append(server.submit(_sym(rng, n), k))
            time.sleep(0.002)
        for f in futs:
            f.result(timeout=300)
        stats = server.stats()
        # Admission (linger) wait: queue-pop minus head submit — measured
        # pre-compile, so XLA time never pollutes the assertion.
        head_wait = max(rec.t_dispatch - min(r.t_submit
                                             for r in rec.requests)
                        for rec in server.dispatch_log)
    assert stats["linger_trims"] >= 1, \
        "hot key never trimmed its linger"
    assert head_wait < 1.0, \
        f"the partial stack waited out the base linger ({head_wait:.2f}s)"


def test_adaptive_linger_off_preserves_base_linger(rng):
    """With adaptive linger disabled the sparse-traffic contract is
    untouched: a lone partial stack waits the full (short) linger."""
    n, k = 8, 2
    with _server(linger_ms=120.0, max_batch=16,
                 adaptive_linger=False) as server:
        fut = server.submit(_sym(rng, n), k)
        t0 = time.monotonic()
        fut.result(timeout=60)
        assert time.monotonic() - t0 >= 0.08
        assert server.stats()["linger_trims"] == 0


# ---------------------------------------------------------------------------
# EeiFleet sticky sessions + failover
# ---------------------------------------------------------------------------


def _fleet(n_replicas: int = 3, **kwargs) -> EeiFleet:
    kwargs.setdefault("server_kwargs", dict(plan=PLAN))
    kwargs.setdefault("cache", SHARED_CACHE)
    kwargs.setdefault("probe_interval_s", 0.01)
    return EeiFleet(n_replicas, **kwargs)


def test_fleet_session_is_sticky(rng):
    """Updates for one session all land on its rendezvous-routed owner;
    results match the oracle end to end."""
    n, k = 10, 2
    with _fleet(3, salt=0) as fleet:
        a = _sym(rng, n)
        sid = fleet.open_session(a, k)
        owner = fleet._sessions[sid].rid
        for _ in range(3):
            u = rng.standard_normal(n)
            a = a + np.outer(u, u)
            res = fleet.submit_update(sid, u).result(timeout=120)
            _assert_conformant(a, res, k)
            assert fleet._sessions[sid].rid == owner
        _assert_conformant(a, fleet.session_result(sid), k)
        stats = fleet.stats()
        assert stats["session_updates"] == 3
        assert stats["session_failovers"] == 0
        fleet.close_session(sid)
        assert fleet.stats()["sessions_open"] == 0


def test_fleet_session_failover_reopens_from_mirror(rng):
    """Killing the owner mid-stream must not lose the session: the update
    resolves as a flagged DegradedResult from a reopen on a healthy
    replica (the mirror already contains the failed update), and the
    warm path then resumes on the new owner."""
    n, k = 10, 2
    with _fleet(3, salt=0) as fleet:
        a = _sym(rng, n)
        sid = fleet.open_session(a, k)
        rec = fleet._sessions[sid]
        old_owner = rec.rid
        u = rng.standard_normal(n)
        a = a + np.outer(u, u)
        fleet._kill_replica(old_owner, reason="test: kill session owner")
        res = fleet.submit_update(sid, u).result(timeout=120)
        assert isinstance(res, DegradedResult)
        assert res.fallback == "session_reopen"
        _assert_conformant(a, res, k)
        assert rec.rid != old_owner
        assert fleet.stats()["session_failovers"] == 1
        # Warm resumption on the new owner: a plain (non-degraded) window.
        w = rng.standard_normal(n) * 0.1
        a = a + np.outer(w, w)
        res2 = fleet.submit_update(sid, w).result(timeout=120)
        assert not isinstance(res2, DegradedResult)
        _assert_conformant(a, res2, k)
        assert rec.rid != old_owner
