"""Stage graph: composition signature validation, the k-windowed spectrum
path's conformance contract, and the windowed planner/serving routing.

The windowed contract, per composition (see docs/ARCHITECTURE.md):

* ``eei_dense_windowed`` — the components stage evaluates only the selected
  rows (prod_diff I-axis = k) with the gap floor and Cauchy denominator
  taken from the full spectrum exactly as the full path takes them, so
  windowed ``topk`` is **bitwise-equal** to the full-spectrum result.
* ``eei_tridiag_windowed`` — the spectrum stage bisects only the k
  index-targeted brackets (**bitwise-equal** eigenvalues: bisection lanes
  are independent) and the components stage evaluates minor determinants
  by the ratio recurrence instead of products over minor spectra — same
  mathematics, different (and better-conditioned) arithmetic, so vectors
  agree to tolerance rather than bitwise.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.engine import (
    Composition,
    SolverEngine,
    SolverPlan,
    StageSig,
    available_compositions,
    composition_for,
    get_backend,
    get_composition,
    plan_for,
)

BACKENDS = ["reference", "jnp", "pallas"]


def _stack(seed: int, b: int, n: int, dtype=np.float64) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((b, n, n)).astype(dtype)
    return jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2)


def _plans(method: str, backend: str):
    full = SolverPlan(method=method, backend=backend, spectrum="full")
    win = SolverPlan(method=method, backend=backend, spectrum="windowed")
    return full, win


# ---------------------------------------------------------------------------
# Composition / registry contracts
# ---------------------------------------------------------------------------


def test_every_registered_composition_validates():
    """Every registered composition must declare compatible stage
    signatures: each stage's requires satisfied upstream, roles in order,
    and the chain ending in the program kind's outputs."""
    names = available_compositions()
    assert {"eigh", "eei_dense", "eei_dense_windowed", "eei_tridiag",
            "eei_tridiag_windowed"} <= set(names)
    for name in names:
        get_composition(name).validate()  # raises on any signature break


def test_composition_validation_rejects_incompatible_signatures():
    broken = Composition(
        name="broken", method="eei_tridiag", windowed=False,
        topk=(
            # components requires lam/mu that nothing provides
            StageSig("components", "eei_full", ("lam", "mu"), ("mags",)),
            StageSig("recover", "tridiag_signs",
                     ("d", "e", "q", "lam_sel", "mag_sel"), ("vecs",)),
        ))
    with pytest.raises(ValueError, match="requires"):
        broken.validate()
    out_of_order = Composition(
        name="disorder", method="eei_tridiag", windowed=False,
        topk=(
            StageSig("spectrum", "eigh", ("a",), ("lam", "v")),
            StageSig("reduce", "householder", ("a",), ("d", "e", "q")),
            StageSig("recover", "eigh_topk", ("lam", "v", "idx"),
                     ("lam_sel", "vecs")),
        ))
    with pytest.raises(ValueError, match="out of order"):
        out_of_order.validate()
    no_output = Composition(
        name="dangling", method="eei_tridiag", windowed=False,
        topk=(StageSig("spectrum", "eigh", ("a",), ("lam", "v")),))
    with pytest.raises(ValueError, match="final state"):
        no_output.validate()


def test_windowed_tridiag_composition_skips_minor_spectra():
    """The windowed payoff is structural: the chain simply has no
    minor-spectra stage (its components stage evaluates the minor
    determinants directly), where the full chain must compute all b*n
    minor spectra."""
    full = composition_for("eei_tridiag", False)
    win = composition_for("eei_tridiag", True)
    assert any(s.role == "minor_spectra" for s in full.topk)
    assert not any(s.role == "minor_spectra" for s in win.topk)
    assert win.solve is None  # full tables always run the full composition
    # eigh has nothing to window: the windowed lookup falls back.
    assert composition_for("eigh", True).name == "eigh"


def test_stage_library_is_open_and_errors_informatively():
    lib = get_backend(SolverPlan(backend="jnp"))
    assert lib.name == "jnp"
    assert "tridiag_eigenvalues_windowed" in lib.stage_names()
    with pytest.raises(AttributeError, match="no stage 'nope'"):
        lib.nope
    marker = object()
    extended = lib.extended(custom_stage=lambda: marker)
    assert extended.custom_stage() is marker
    assert "custom_stage" not in lib.stage_names()  # original untouched


# ---------------------------------------------------------------------------
# Windowed-vs-full conformance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k,largest", [(1, True), (3, False), (4, True)])
def test_windowed_dense_topk_bitwise_equals_full(backend, k, largest):
    a = _stack(0, 3, 18)
    full, win = _plans("eei_dense", backend)
    tf = SolverEngine(full).topk(a, k, largest)
    tw = SolverEngine(win).topk(a, k, largest)
    np.testing.assert_array_equal(np.asarray(tf.eigenvalues),
                                  np.asarray(tw.eigenvalues))
    np.testing.assert_array_equal(np.asarray(tf.vectors),
                                  np.asarray(tw.vectors))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k,largest", [(1, True), (3, False), (4, True)])
def test_windowed_tridiag_topk_matches_full(backend, k, largest):
    """Windowed eigenvalues are bitwise; vectors agree to f64 tolerance
    (the recurrence components stage is different — better-conditioned —
    arithmetic for the same products) and satisfy the eigen-residual."""
    a = _stack(1, 3, 18)
    full, win = _plans("eei_tridiag", backend)
    tf = SolverEngine(full).topk(a, k, largest)
    tw = SolverEngine(win).topk(a, k, largest)
    np.testing.assert_array_equal(np.asarray(tf.eigenvalues),
                                  np.asarray(tw.eigenvalues))
    vf, vw = np.asarray(tf.vectors), np.asarray(tw.vectors)
    err = np.minimum(np.abs(vw - vf), np.abs(vw + vf)).max()
    assert err < 1e-7, err
    res = jnp.einsum("bij,bkj->bki", a, tw.vectors) \
        - tw.eigenvalues[..., None] * tw.vectors
    assert float(jnp.abs(res).max()) < 1e-7


@pytest.mark.parametrize("backend", BACKENDS)
def test_windowed_eigenvalues_bitwise(backend):
    """eigenvalues(k=...) runs k index-targeted bisection lanes and must be
    bitwise-equal to the matching slice of the full spectrum."""
    a = _stack(2, 3, 17)
    for method in ("eei_dense", "eei_tridiag"):
        eng = SolverEngine(SolverPlan(method=method, backend=backend))
        lam = eng.eigenvalues(a)
        for k, largest in [(1, True), (2, False), (5, True)]:
            win = eng.eigenvalues(a, k=k, largest=largest)
            ref = lam[:, -k:] if largest else lam[:, :k]
            np.testing.assert_array_equal(np.asarray(win), np.asarray(ref))


def test_windowed_sharded_matches_jnp():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    a = _stack(3, 4, 16)
    plan = SolverPlan(method="eei_tridiag", backend="sharded", mesh=mesh,
                      spectrum="windowed")
    t_sh = SolverEngine(plan).topk(a, 2)
    t_jnp = SolverEngine(SolverPlan(
        method="eei_tridiag", backend="jnp", spectrum="windowed")).topk(a, 2)
    np.testing.assert_allclose(np.asarray(t_sh.eigenvalues),
                               np.asarray(t_jnp.eigenvalues),
                               rtol=1e-12, atol=1e-12)
    ev = SolverEngine(plan).eigenvalues(a, k=2)
    assert ev.shape == (4, 2)


# One property case: (n, k_raw, largest, backend index, seed).
_CASE = st.tuples(st.integers(3, 14), st.integers(0, 3), st.booleans(),
                  st.integers(0, len(BACKENDS) - 1), st.integers(0, 999))


@settings(max_examples=8, deadline=None)
@given(case=_CASE)
def test_property_windowed_topk_conforms_to_full_oracle(case):
    """Hypothesis property over random (n, k, largest) x backend: the
    windowed composition's topk against the full-spectrum
    ``SolverEngine.topk`` oracle — eigenvalues bitwise on both methods,
    dense vectors bitwise, tridiag vectors to f64 tolerance."""
    n, k_raw, largest, backend_i, seed = case
    k = 1 + k_raw % n
    backend = BACKENDS[backend_i]
    a = _stack(seed, 2, n)
    for method, bitwise_vecs in (("eei_dense", True), ("eei_tridiag", False)):
        full, win = _plans(method, backend)
        tf = SolverEngine(full).topk(a, k, largest)
        tw = SolverEngine(win).topk(a, k, largest)
        np.testing.assert_array_equal(np.asarray(tf.eigenvalues),
                                      np.asarray(tw.eigenvalues))
        vf, vw = np.asarray(tf.vectors), np.asarray(tw.vectors)
        if bitwise_vecs:
            np.testing.assert_array_equal(vf, vw)
        else:
            err = np.minimum(np.abs(vw - vf), np.abs(vw + vf)).max()
            assert err < 1e-6, (n, k, largest, backend, err)


# ---------------------------------------------------------------------------
# Planner + serving routing
# ---------------------------------------------------------------------------


def test_planner_windows_topk_from_calibrated_k_frac():
    from repro.engine import CalibrationTable, set_table

    try:
        set_table(CalibrationTable(
            eigh_crossover_n=4, dense_crossover_n=8,
            prod_diff_blocks=(32, 32, 32), sturm_blocks=(8, 64),
            windowed_k_frac=0.25))
        # k/n <= 0.25 -> windowed; above -> full; no k -> full.
        assert plan_for((32, 32), k=8).spectrum == "windowed"
        assert plan_for((32, 32), k=9).spectrum == "full"
        assert plan_for((32, 32)).spectrum == "full"
        # k >= n routes to eigh, which has nothing to window.
        assert plan_for((32, 32), k=32).method == "eigh"
        assert plan_for((32, 32), k=32).spectrum == "full"
        # explicit override wins over the crossover
        assert plan_for((32, 32), k=16,
                        spectrum="windowed").spectrum == "windowed"
    finally:
        set_table(None)


def test_server_stream_through_windowed_plan_is_conformant():
    """The acceptance stream: top-k requests served through the windowed
    composition must be bitwise-equal to the same-plan SolverEngine oracle
    replayed on every recorded dispatch, and (at k=1) carry bitwise the
    same eigenvalues as the full-spectrum plan's serving path."""
    from repro.engine import EeiServer

    rng = np.random.default_rng(7)
    stream = [((lambda x: ((x + x.T) / 2).astype(np.float32))(
        rng.standard_normal((12, 12))), 1) for _ in range(6)]
    results = {}
    for spectrum in ("full", "windowed"):
        plan = SolverPlan(method="eei_tridiag", backend="jnp",
                          spectrum=spectrum)
        server = EeiServer(plan, max_batch=4, record_dispatches=True)
        futs = [server.submit(a, k) for a, k in stream]
        server.flush()
        results[spectrum] = [f.result() for f in futs]
        for rec in server.dispatch_log:  # same-plan oracle, bitwise
            ref = SolverEngine(rec.plan).topk(
                jnp.asarray(rec.stack), rec.bucket.k, rec.bucket.largest)
            lam = np.asarray(ref.eigenvalues)
            for row, req in enumerate(rec.requests):
                np.testing.assert_array_equal(
                    req.future.result().eigenvalues, lam[row, -req.k:])
    for rf, rw in zip(results["full"], results["windowed"]):
        np.testing.assert_array_equal(rf.eigenvalues, rw.eigenvalues)
