"""End-to-end behaviour tests: training drives loss down; the EEI spectral
engine runs inside the loop; serve path generates; small-mesh dry-run
lowers + compiles (the same code path the production dry-run uses)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import get_config, reduced_config
from repro.data import PrefetchIterator, make_synthetic
from repro.models.lm import LanguageModel
from repro.optim import AdamW, EigenPre
from repro.train import TrainState, make_train_step
from repro.train.steps import cast_tree


def _train(cfg, optimizer, steps=30, seq=16, batch=4):
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, optimizer.init(params),
                       jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(model, optimizer,
                                   compute_dtype=jnp.float32))
    shape = ShapeConfig("t", seq, batch, "train")
    src = make_synthetic(cfg, shape, seed=0)
    losses = []
    for i in range(steps):
        batch_np = src.global_batch_at(i % 4)  # small repeating set
        state, metrics = step(state,
                              {k: jnp.asarray(v) for k, v in batch_np.items()})
        losses.append(float(np.asarray(metrics["loss"])))
    return losses


def test_training_reduces_loss_adamw():
    cfg = reduced_config(get_config("codeqwen1.5-7b"))
    losses = _train(cfg, AdamW(lr=3e-3, weight_decay=0.0))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_training_reduces_loss_eigenpre():
    """The paper's technique in the training loop (spectral preconditioner)."""
    cfg = reduced_config(get_config("codeqwen1.5-7b"))
    losses = _train(cfg, EigenPre(adamw=AdamW(lr=3e-3, weight_decay=0.0),
                                  rank=2, refresh_every=10))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_moe_training_reduces_loss():
    cfg = reduced_config(get_config("kimi-k2-1t-a32b"))
    losses = _train(cfg, AdamW(lr=3e-3, weight_decay=0.0), steps=25)
    assert losses[-1] < losses[0] - 0.3, losses[::5]


def test_serve_generates_tokens():
    cfg = reduced_config(get_config("gemma2-2b"))
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    logits, caches = model.prefill(params, batch, 16)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    outs = [tok]
    for i in range(4):
        logits, caches = model.decode_step(params, caches, tok,
                                           jnp.asarray(8 + i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(tok)
    gen = np.stack([np.asarray(t) for t in outs], axis=1)
    assert gen.shape == (2, 5)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()


@pytest.mark.parametrize("arch", ["gemma2-2b", "zamba2-2.7b",
                                  "deepseek-v3-671b"])
def test_dryrun_cell_small_mesh(arch):
    """Same lowering path as the production dry-run, on a 1x1 mesh with a
    reduced config and tiny shape — catches sharding/lowering regressions in
    seconds."""
    from repro.launch import dryrun_lib
    from repro.train import steps as steps_lib

    cfg = reduced_config(get_config(arch))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("train_tiny", 16, 2, "train")
    lowered = dryrun_lib.lower_cell(cfg, shape, mesh)
    out = dryrun_lib.compile_and_extract(lowered)
    assert out["cost"].get("flops", 0) > 0
    shape_d = ShapeConfig("decode_tiny", 16, 2, "decode")
    lowered_d = dryrun_lib.lower_cell(cfg, shape_d, mesh)
    out_d = dryrun_lib.compile_and_extract(lowered_d)
    assert out_d["cost"].get("flops", 0) > 0


def test_distributed_eei_single_device_mesh():
    from repro.core import distributed, identity

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(0)
    a = rng.standard_normal((8, 8))
    a = jnp.asarray((a + a.T) / 2, jnp.float32)
    with mesh:
        mags = distributed.minor_sharded_magnitudes(a, mesh, axis="model")
    ref = identity.eigenmatrix_magnitudes(a)
    np.testing.assert_allclose(np.asarray(mags), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)
    lam = identity.matrix_spectrum(a)
    mu = identity.minor_spectra(a)
    with mesh:
        comp = distributed.term_sharded_component(lam, mu[3], 2, mesh,
                                                  axis="model")
    np.testing.assert_allclose(float(comp), float(ref[2, 3]), rtol=1e-4)


def test_engine_sharded_backend_single_device_mesh():
    """The SolverEngine sharded backend (batch axis = data) on a host mesh —
    the same code path the production meshes run."""
    from repro.engine import SolverEngine, SolverPlan

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(1)
    a = rng.standard_normal((3, 12, 12))
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2, jnp.float32)
    plan = SolverPlan(method="eei_tridiag", backend="sharded", mesh=mesh)
    lam, mags = SolverEngine(plan).solve(a)
    lam_ref, v_ref = jax.vmap(jnp.linalg.eigh)(a)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(mags), np.asarray(jnp.swapaxes(v_ref * v_ref, -1, -2)),
        rtol=1e-3, atol=1e-4)


def test_input_specs_cover_all_cells():
    """Every (arch x shape) cell has well-defined abstract inputs."""
    from repro.configs.base import shape_applicable
    from repro.configs.registry import ARCHS
    from repro.train.steps import input_specs

    n_checked = 0
    for name in ARCHS:
        cfg = get_config(name)
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert specs, (name, shape.name)
            n_checked += 1
    assert n_checked >= 30
