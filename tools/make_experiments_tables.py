"""Render EXPERIMENTS.md tables from artifacts/dryrun/*.json.

    PYTHONPATH=src python tools/make_experiments_tables.py [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_s(x):
    if x is None:
        return "-"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def load(d):
    cells = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        with open(p) as f:
            c = json.load(f)
        tag = "multipod" if c.get("chips", 0) > 256 else "pod"
        cells[(c["arch"], c["shape"], tag)] = c
    return cells


ARCH_ORDER = ["xlstm-125m", "codeqwen1.5-7b", "starcoder2-7b", "gemma2-2b",
              "granite-20b", "kimi-k2-1t-a32b", "deepseek-v3-671b",
              "whisper-large-v3", "llama-3.2-vision-90b", "zamba2-2.7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def dryrun_table(cells, tag):
    print(f"\n### Dry-run — {tag} mesh\n")
    print("| arch | shape | status | per-device args | per-device temp | "
          "HLO flops/dev | coll bytes/dev |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            c = cells.get((a, s, tag))
            if c is None:
                print(f"| {a} | {s} | MISSING | | | | |")
                continue
            if c["status"] == "skipped":
                print(f"| {a} | {s} | skipped ({c['reason'][:40]}...) | | | | |")
                continue
            full = c.get("full", {})
            mem = full.get("memory", {})
            cost = full.get("cost", {})
            coll = full.get("collectives", {})
            print(f"| {a} | {s} | {c['status']} "
                  f"| {fmt_bytes(mem.get('argument_size_in_bytes'))} "
                  f"| {fmt_bytes(mem.get('temp_size_in_bytes'))} "
                  f"| {cost.get('flops', 0):.3g} "
                  f"| {fmt_bytes(coll.get('total', 0))} |")


def roofline_table(cells):
    print("\n### Roofline — single-pod (16x16 = 256 chips)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | dominant | "
          "MODEL_FLOPS | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            c = cells.get((a, s, "pod"))
            if c is None or c.get("status") == "skipped" or "roofline" not in c:
                continue
            r = c["roofline"]
            print(f"| {a} | {s} | {fmt_s(r['t_compute_s'])} "
                  f"| {fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} "
                  f"| **{r['dominant']}** | {r['model_flops']:.3g} "
                  f"| {r['useful_flops_ratio']:.3f} "
                  f"| {r['roofline_fraction']:.3f} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--which", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    cells = load(args.dir)
    if args.which in ("all", "dryrun"):
        dryrun_table(cells, "pod")
        dryrun_table(cells, "multipod")
    if args.which in ("all", "roofline"):
        roofline_table(cells)


if __name__ == "__main__":
    main()
